"""E7 — streaming data-mining apps on the tick core (serve/apps.py).

Drives the streaming Lloyd and ε-join services through a synthetic
insert stream and reports sustained requests/sec and p99 tick latency.
Each app row is stamped ``differential_ok`` — the streaming result is
checked against its one-shot batch oracle (bit-identical centroids for
Lloyd at decay=1.0; equal pair set for the join), so serving throughput
can never drift away from a correctness anchor.

Also measures the admission-coalescing claim: each tick coalesces
``GROUP`` insert requests into one multi-tile cohort, and
Hilbert-sorting that cohort gives the resident-index probe tighter
per-tile key ranges than FIFO order — fewer candidate rows and
scheduled tile pairs per tick, hence lower warm (second identical
stream, compile amortised) tick time.  A single-request tick is one
tile either way ([min, max] is order-invariant), so the win is
specifically a *coalescing* win.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.serve import StreamKMeans, StreamSimJoin

POINTS, CHUNK, GROUP, DIMS = 2048, 64, 8, 3
K, ITERS = 16, 5
EPS = 0.08


def _chunks(seed=0):
    rng = np.random.default_rng(seed)
    data = rng.uniform(0, 1, size=(POINTS, DIMS)).astype(np.float32)
    return data, [data[i : i + CHUNK] for i in range(0, POINTS, CHUNK)]


def _drive(svc, chunks, ticks_after=0):
    """Submit GROUP insert requests per tick (the coalescing pattern);
    returns wall time for the whole stream."""
    t0 = time.perf_counter()
    for a in range(0, len(chunks), GROUP):
        for c in chunks[a : a + GROUP]:
            svc.insert(c)
        svc.tick()
    for _ in range(ticks_after):
        svc.tick()
    return time.perf_counter() - t0


def _kmeans_rows(chunks):
    svc = StreamKMeans(K, bp=256, bc=32)
    dt = _drive(svc, chunks, ticks_after=ITERS)
    p99 = svc.stats.p99() * 1e3

    # batch oracle on a FULLY-inserted set: admit everything in tick 1,
    # run the same number of Lloyd ticks, demand bit-identity
    chk = StreamKMeans(K, bp=256, bc=32)
    for c in chunks:
        chk.insert(c)
    for _ in range(ITERS):
        chk.tick()
    # oracle over the points in the service's stored (coalesced) order —
    # Lloyd is order-sensitive through init, so "same input" means the
    # admitted order, not the submission order
    c_b, a_b = ops.kmeans_lloyd(jnp.asarray(chk.points()), K, iters=ITERS,
                                bp=256, bc=32)
    ok = bool(
        np.array_equal(chk.centroids(), np.asarray(c_b))
        and np.array_equal(chk.assignment(), np.asarray(a_b))
    )
    return [
        {
            "bench": "apps_serving",
            "name": "kmeans_req_s",
            "value": round(len(chunks) / dt, 1),
            "derived": f"insert req/s; {POINTS} pts k={K} decay=1.0; "
                       f"differential_ok={ok}",
        },
        {
            "bench": "apps_serving",
            "name": "kmeans_p99_tick_ms",
            "value": round(p99, 2),
            "derived": f"p99 over {svc.stats.total_ticks} ticks; "
                       f"lloyd_dispatches={int(svc.stats.total('lloyd_dispatch'))}",
        },
    ]


def _join_service(coalesce):
    # bp=64: tight enough tiles that the per-tile curve-interval prune
    # has structure to work with — the hilbert-vs-fifo rows measure it
    return StreamSimJoin(
        EPS, bp=64, coalesce=coalesce,
        bounds=(np.zeros(DIMS, np.float32), np.ones(DIMS, np.float32)),
    )


def _join_rows(chunks):
    rows = []
    warm_ms = {}
    for coalesce in ("hilbert", "fifo"):
        _drive(_join_service(coalesce), chunks)        # cold: trace+compile
        # warm passes are cheap once compiled — take the min of 3 mean
        # tick times so one noisy pass can't flip the comparison row
        best = float("inf")
        for _ in range(3):
            svc = _join_service(coalesce)
            dt = _drive(svc, chunks)                   # warm, measured
            best = min(best, svc.stats.mean() * 1e3)
        warm_ms[coalesce] = best
        if coalesce == "hilbert":
            want = np.asarray(
                ops.simjoin_pairs(jnp.asarray(svc.points_by_id()), EPS),
                dtype=np.int64,
            )
            want = want[np.lexsort((want[:, 1], want[:, 0]))]
            ok = bool(np.array_equal(svc.pairs(), want))
            rows.append({
                "bench": "apps_serving",
                "name": "simjoin_req_s",
                "value": round(len(chunks) / dt, 1),
                "derived": f"insert req/s; {POINTS} pts eps={EPS} "
                           f"pairs={len(want)}; differential_ok={ok}",
            })
            rows.append({
                "bench": "apps_serving",
                "name": "simjoin_p99_tick_ms",
                "value": round(svc.stats.p99() * 1e3, 2),
                "derived": f"p99 over {svc.stats.total_ticks} ticks; "
                           f"tiles={int(svc.stats.total('tiles_scheduled'))} "
                           f"pruned={int(svc.stats.total('tiles_pruned'))}",
            })
    hw = warm_ms["hilbert"] < warm_ms["fifo"]
    for coalesce in ("hilbert", "fifo"):
        rows.append({
            "bench": "apps_serving",
            "name": f"simjoin_warm_tick_{coalesce}_ms",
            "value": round(warm_ms[coalesce], 2),
            "derived": f"mean warm tick; coalesce={coalesce}; "
                       f"hilbert_wins={hw}",
        })
    return rows


def run() -> list[dict]:
    _, chunks = _chunks()
    return _kmeans_rows(chunks) + _join_rows(chunks)
