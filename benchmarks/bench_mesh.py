"""E5b — beyond-paper: Hilbert device layout for the ICI torus.

Logical (data, model) neighbours should be physically adjacent on the 2-D
torus.  We compare torus hop counts between raster (default) and
FUR-Hilbert device orderings for the collective patterns the framework
uses: ring all-reduce over each mesh axis row/column.
"""
from __future__ import annotations

import numpy as np

from repro.launch.mesh import hilbert_grid_permutation


def _phys_coords(n: int, m: int, perm: np.ndarray) -> np.ndarray:
    """perm[logical_linear] = physical_linear; physical grid row-major."""
    phys = perm.reshape(n, m)
    return np.stack([phys // m, phys % m], axis=-1)  # (n, m, 2)


def _torus_hops(a: np.ndarray, b: np.ndarray, n: int, m: int) -> int:
    d0 = np.abs(a[..., 0] - b[..., 0])
    d1 = np.abs(a[..., 1] - b[..., 1])
    return int(np.sum(np.minimum(d0, n - d0) + np.minimum(d1, m - d1)))


def run(n: int = 16, m: int = 16) -> list[dict]:
    rows = []
    raster = np.arange(n * m, dtype=np.int64)
    hilb = hilbert_grid_permutation(n, m)
    for name, perm in (("raster", raster), ("hilbert", hilb)):
        c = _phys_coords(n, m, perm)
        # ring neighbours along the logical "model" axis (rows) and
        # "data" axis (columns), wrap-around included
        hops_model = _torus_hops(c, np.roll(c, -1, axis=1), n, m)
        hops_data = _torus_hops(c, np.roll(c, -1, axis=0), n, m)
        rows.append({
            "bench": "mesh_layout", "name": f"{name}_ring_hops",
            "value": hops_model + hops_data,
            "derived": f"model-axis={hops_model} data-axis={hops_data}",
        })
    return rows
