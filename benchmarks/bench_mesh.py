"""E5b — beyond-paper: Hilbert device layout for the ICI torus.

Logical (data, model) neighbours should be physically adjacent on the 2-D
torus.  We compare torus hop counts between raster (default) and
FUR-Hilbert device orderings for the collective patterns the framework
uses: ring all-reduce over each mesh axis row/column.
"""
from __future__ import annotations

import numpy as np

from repro.launch.mesh import hilbert_grid_permutation


def _phys_coords(n: int, m: int, perm: np.ndarray) -> np.ndarray:
    """perm[logical_linear] = physical_linear; physical grid row-major."""
    phys = perm.reshape(n, m)
    return np.stack([phys // m, phys % m], axis=-1)  # (n, m, 2)


def _torus_hops(a: np.ndarray, b: np.ndarray, n: int, m: int) -> int:
    d0 = np.abs(a[..., 0] - b[..., 0])
    d1 = np.abs(a[..., 1] - b[..., 1])
    return int(np.sum(np.minimum(d0, n - d0) + np.minimum(d1, m - d1)))


def run(n: int = 16, m: int = 16) -> list[dict]:
    rows = []
    raster = np.arange(n * m, dtype=np.int64)
    hilb = hilbert_grid_permutation(n, m)
    for name, perm in (("raster", raster), ("hilbert", hilb)):
        c = _phys_coords(n, m, perm)
        # ring neighbours along the logical "model" axis (rows) and
        # "data" axis (columns), wrap-around included
        hops_model = _torus_hops(c, np.roll(c, -1, axis=1), n, m)
        hops_data = _torus_hops(c, np.roll(c, -1, axis=0), n, m)
        rows.append({
            "bench": "mesh_layout", "name": f"{name}_ring_hops",
            "value": hops_model + hops_data,
            "derived": f"model-axis={hops_model} data-axis={hops_data}",
        })
    rows += _halo_bytes_rows()
    return rows


def _halo_bytes_rows() -> list[dict]:
    """Halo-exchange traffic of the sharded ε-join as the mesh widens:
    bytes per shard for boundary strips vs full replication at every
    simulable mesh size.  More shards → narrower resident curve ranges →
    more boundary per shard; replication is flat (every shard always
    receives all of x).  jax is imported lazily so the hop-count rows
    above stay numpy-only."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.sharded import simjoin_sharded_volume
    from repro.launch.mesh import make_app_mesh

    rng = np.random.default_rng(23)
    x = jnp.asarray(rng.uniform(size=(1024, 2)), jnp.float32)
    rows = []
    for s in (1, 2, 4, 8):
        if s > len(jax.devices()):
            continue
        mesh = make_app_mesh(s)
        kw = dict(mesh=mesh, bp=64, hilbert_order=True, interpret=True)
        vh = simjoin_sharded_volume(x, 0.04, halo=True, **kw)
        vr = simjoin_sharded_volume(x, 0.04, halo=False, **kw)
        rows.append({
            "bench": "mesh_halo", "name": f"simjoin_halo_bytes_mesh{s}",
            "value": int(vh["bytes_per_shard"]),
            "bytes_per_shard": int(vh["bytes_per_shard"]),
            "derived": f"bytes/shard boundary strips (replicated "
                       f"{vr['bytes_per_shard']}); N=1024 uniform 2-D",
        })
    return rows
