"""Benchmark harness — one module per paper table/figure.

  E1 bench_locality   — Fig. 1(e) cache-miss curves + reload economy
  E2 bench_codec      — §3/§5 coding & generation throughput
  E3 bench_matmul     — §1 matmul traffic model + kernel check
  E4 bench_apps       — §7 k-means / simjoin / FW / Cholesky
  E5 bench_attention  — §6.2 jump-over on causal attention
  E5b bench_mesh      — beyond-paper Hilbert ICI layout
  E6 bench_serving    — dense vs Hilbert-paged vs flash-paged decode
  E7 bench_apps_serving — streaming Lloyd / ε-join on the tick core
  E8 bench_autotune   — measured schedule choices: chosen vs default

Prints ``bench,name,value,derived`` CSV.  ``--json [PATH]`` additionally
records the rows as JSON (default ``BENCH_curves.json``) so the perf
trajectory is tracked across PRs.  Roofline terms come from
``python -m repro.launch.dryrun`` (they need the 512-device env), not
from here.
"""
from __future__ import annotations

import json
import sys
import time


def main() -> None:
    from . import (
        bench_apps,
        bench_apps_serving,
        bench_attention,
        bench_autotune,
        bench_codec,
        bench_locality,
        bench_matmul,
        bench_mesh,
        bench_serving,
    )

    modules = [
        ("locality", bench_locality),
        ("codec", bench_codec),
        ("matmul", bench_matmul),
        ("apps", bench_apps),
        ("attention", bench_attention),
        ("mesh", bench_mesh),
        ("serving", bench_serving),
        ("apps_serving", bench_apps_serving),
        ("autotune", bench_autotune),
    ]
    args = sys.argv[1:]
    json_path = None
    if "--json" in args:
        # --json [PATH.json]: only a *.json token is taken as the path, so
        # a typo'd bench selector is never silently consumed as a filename
        i = args.index("--json")
        args.pop(i)
        json_path = "BENCH_curves.json"
        if i < len(args) and args[i].endswith(".json"):
            json_path = args.pop(i)
    selected = set(args)
    unknown = selected - {name for name, _ in modules}
    if unknown:
        print(f"# unknown bench(es): {sorted(unknown)}; "
              f"known: {[n for n, _ in modules]}", file=sys.stderr)
    print("bench,name,value,derived")
    t0 = time.time()
    collected: list[dict] = []
    run_counts: dict[str, int] = {}
    for name, mod in modules:
        if selected and name not in selected:
            continue
        n_before = len(collected)
        for row in mod.run():
            # every row carries the suite (module) that produced it — the
            # summary counts below are validated against this tag, so a
            # module emitting under a foreign "bench" label can't skew
            # another suite's trajectory silently
            row["suite"] = name
            collected.append(row)
            derived = str(row.get("derived", "")).replace(",", ";")
            print(f"{row['bench']},{row['name']},{row['value']},{derived}")
        run_counts[name] = len(collected) - n_before
    if json_path:
        if not collected:
            # an empty snapshot silently breaks the perf trajectory — fail
            # loudly instead of committing {"rows": []}
            print(f"# refusing to write {json_path}: 0 rows collected",
                  file=sys.stderr)
            sys.exit(1)
        # stable top-level summary so BENCH_*.json snapshots diff cleanly
        # across PRs: schema version, sorted suite names, per-suite row
        # counts.  "rows" stays the flat list earlier tooling reads.
        # Counted two independent ways — per module while running, and
        # from the per-row "suite" tags at write time — and the snapshot
        # is refused if they disagree (a row dropped, duplicated or
        # re-tagged between collection and serialisation).
        row_counts = {k: v for k, v in run_counts.items() if v}
        tag_counts: dict[str, int] = {}
        for row in collected:
            tag_counts[row["suite"]] = tag_counts.get(row["suite"], 0) + 1
        summary = {
            "schema_version": 4,
            "suites": sorted(row_counts),
            "row_counts": {k: row_counts[k] for k in sorted(row_counts)},
            "total_rows": len(collected),
        }
        if (
            tag_counts != summary["row_counts"]
            or sum(tag_counts.values()) != summary["total_rows"]
        ):
            print(f"# refusing to write {json_path}: summary/row mismatch "
                  f"{summary['row_counts']} vs {tag_counts}", file=sys.stderr)
            sys.exit(1)
        with open(json_path, "w") as f:
            json.dump({"summary": summary, "rows": collected}, f, indent=1)
        print(f"# wrote {json_path} ({len(collected)} rows, "
              f"{len(row_counts)} suites)", file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
