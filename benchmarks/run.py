"""Benchmark harness — one module per paper table/figure.

  E1 bench_locality   — Fig. 1(e) cache-miss curves + reload economy
  E2 bench_codec      — §3/§5 coding & generation throughput
  E3 bench_matmul     — §1 matmul traffic model + kernel check
  E4 bench_apps       — §7 k-means / simjoin / FW / Cholesky
  E5 bench_attention  — §6.2 jump-over on causal attention
  E5b bench_mesh      — beyond-paper Hilbert ICI layout

Prints ``bench,name,value,derived`` CSV.  Roofline terms come from
``python -m repro.launch.dryrun`` (they need the 512-device env), not
from here.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (
        bench_apps,
        bench_attention,
        bench_codec,
        bench_locality,
        bench_matmul,
        bench_mesh,
    )

    modules = [
        ("locality", bench_locality),
        ("codec", bench_codec),
        ("matmul", bench_matmul),
        ("apps", bench_apps),
        ("attention", bench_attention),
        ("mesh", bench_mesh),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("bench,name,value,derived")
    t0 = time.time()
    for name, mod in modules:
        if only and only != name:
            continue
        for row in mod.run():
            derived = str(row.get("derived", "")).replace(",", ";")
            print(f"{row['bench']},{row['name']},{row['value']},{derived}")
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
