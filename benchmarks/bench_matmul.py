"""E3 — matmul HBM-traffic model + kernel check (paper §1/§7).

The paper's cache-oblivious matmul claim in TPU terms: the schedule order
determines how many operand panels the Pallas pipeline re-fetches
(an operand block is re-copied HBM→VMEM iff its index changed between
consecutive grid steps).  We model traffic for all curves across shapes
incl. the non-pow2 tile grids of the assigned archs (FUR overlay), and
run the actual kernel (interpret mode) for a correctness+time spot check.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import matmul_traffic_bytes, tile_schedule
from repro.kernels import ops, ref

SHAPES = [
    # (M, N, K, bm, bn, bk)  — grid shapes from real layers
    (4096, 4096, 4096, 256, 256, 256),     # 16x16 tiles, square pow2
    (4096, 11008, 4096, 256, 256, 256),    # llama-ish d_ff (43 tiles, non-pow2)
    (8192, 2048, 8192, 256, 256, 256),     # wide x narrow
    (5120, 13824, 5120, 256, 256, 256),    # qwen2.5-14b mlp
]


def run() -> list[dict]:
    rows = []
    for (M, N, K, bm, bn, bk) in SHAPES:
        mt, nt, kt = M // bm, N // bn, K // bk
        base = None
        for curve in ("row", "zigzag", "zorder", "hilbert", "fur"):
            sched = tile_schedule(curve, mt, nt)
            t = matmul_traffic_bytes(sched, bm=bm, bn=bn, bk=bk, k_tiles=kt)
            if curve == "row":
                base = t["total_bytes"]
            rows.append({
                "bench": "matmul_traffic",
                "name": f"{curve}_{M}x{N}x{K}",
                "value": round(t["total_bytes"] / 2**20, 1),
                "derived": (
                    f"MiB; a_loads={t['a_loads']} b_loads={t['b_loads']} "
                    f"vs_row={t['total_bytes']/base:.3f}"
                ),
            })
    # kernel spot check (small, interpret mode)
    a = jnp.asarray(np.random.default_rng(0).normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).normal(size=(256, 256)), jnp.float32)
    for curve in ("row", "fur"):
        ops.matmul(a, b, curve=curve, bm=64, bn=64, bk=64,
                   interpret=True).block_until_ready()  # warmup/compile
        t0 = time.perf_counter()
        out = ops.matmul(a, b, curve=curve, bm=64, bn=64, bk=64, interpret=True)
        out.block_until_ready()
        dt = time.perf_counter() - t0
        err = float(jnp.abs(out - ref.matmul(a, b)).max())
        rows.append({
            "bench": "matmul_kernel",
            "name": f"{curve}_256_interpret",
            "value": round(dt * 1e3, 1),
            "derived": f"ms; max_err={err:.2e}",
        })
    return rows
