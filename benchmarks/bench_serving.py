"""E6 — serving decode: dense vs Hilbert-paged KV cache vs flash-paged.

Drives the continuous-batching ``ServeEngine`` through the same request
stream in its three cache/attention modes and reports tokens/sec and
per-step decode latency for each, for a GQA arch and an MLA arch.  Every
mode row is stamped ``differential_ok`` — greedy outputs token-identical
to the retained dense XLA path (the CI bench gate requires True), so
the perf trajectory can never drift away from a correctness anchor.

Also reports the page-layout locality claim behind the design: under
interleaved slot growth with eviction churn, the curve page layout's
decode gather stream decomposes into fewer contiguous memory runs than
naive first-fit allocation (Netay's clustering property applied to KV
paging; ``PagedKVCache.gather_runs``).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import init_params
from repro.serve import PagedKVCache, ServeEngine

MODES = [
    ("dense", dict(paged=False)),
    ("paged", dict(paged=True, attn_impl="xla")),
    ("flash_paged", dict(paged=True, attn_impl="flash")),
]


def _drive(cfg, params, mode_kw, prompts, max_new):
    """One full serve of ``prompts``.  Returns (outputs, decode_s, steps).

    Decode time excludes admission/prefill ticks — per-step latency is
    the steady-state metric a serving deployment sees."""
    eng = ServeEngine(
        cfg, params, num_slots=4, max_len=96, page_size=16, **mode_kw
    )
    reqs = [eng.submit(list(p), max_new=max_new) for p in prompts]
    steps = 0
    decode_s = 0.0
    while (eng._queue or eng.active.any()) and steps < 10_000:
        t0 = time.perf_counter()
        eng.step()
        decode_s += time.perf_counter() - t0
        steps += 1
    return [r.out for r in reqs], decode_s, steps


def _time_prefill(cfg, params, mode, prompts, max_len):
    """Warm admission wall-time for one prefill mode.  Returns
    (prefill tokens/sec, greedy outputs).  The first pass compiles the
    dispatch (jitted fns are module-level, so the executable cache
    carries to the fresh timing engine); the second pass is the
    measurement.  ``_attach()`` runs the admission phase alone, so the
    timer sees prefill and nothing else."""
    def once():
        eng = ServeEngine(
            cfg, params, num_slots=len(prompts), max_len=max_len,
            paged=True, attn_impl="xla", page_size=16, prefill=mode,
        )
        reqs = [eng.submit(list(p), max_new=2) for p in prompts]
        t0 = time.perf_counter()
        eng._attach()
        jax.block_until_ready(eng.cache)
        dt = time.perf_counter() - t0
        eng.run_until_done()
        return dt, [r.out for r in reqs]

    once()  # cold: trace + compile
    dt, outs = once()
    toks = sum(len(p) - 1 for p in prompts)  # prefill covers prompt[:-1]
    return toks / dt if dt else 0.0, outs


def _drive_sharing(cfg, params, sharing, prompts, max_new):
    """Serve shared-prefix ``prompts`` through 2 paged slots with prefix
    sharing on or off; returns (outputs, pages allocated)."""
    eng = ServeEngine(
        cfg, params, num_slots=2, max_len=96, paged=True, attn_impl="xla",
        page_size=16, prefill="compiled", prefix_sharing=sharing,
    )
    reqs = [eng.submit(list(p), max_new=max_new) for p in prompts]
    eng.run_until_done()
    return [r.out for r in reqs], eng.kv_pages.stat_allocated


def _sharing_churn(mode: str, seed: int) -> int:
    """Allocator-level admission/growth/eviction churn with COW prefix
    sharing on (``shared``) or off (``unshared``); returns final gather
    runs.  Gates the layout claim: sharing's donor pages and COW copies
    must not shred the decode gather stream."""
    rng = np.random.default_rng(seed)
    B, MP, ps = 4, 8, 16
    c = PagedKVCache(B, MP, ps, num_pages=B * MP + 8, layout="hilbert")
    prefix = rng.integers(0, 512, size=40).tolist()
    pos = np.zeros(B, dtype=int)

    def admit(s):
        tail = rng.integers(0, 512, size=int(rng.integers(4, 12))).tolist()
        toks = prefix + tail
        m = c.share_prefix(s, toks) if mode == "shared" else 0
        c.ensure_pos(s, len(toks) - 1)
        c.prepare_write(s, m, len(toks))
        if mode == "shared":
            c.register_prefix(s, toks)
        pos[s] = len(toks)

    for s in range(B):
        admit(s)
    for _ in range(200):
        s = int(rng.integers(0, B))
        if pos[s] >= MP * ps - 2 or rng.random() < 0.1:
            c.free_slot(s)
            admit(s)
        else:
            c.prepare_write(s, int(pos[s]), int(pos[s]) + 1)
            c.ensure_pos(s, int(pos[s]))
            pos[s] += 1
    return c.gather_runs()


def _layout_churn(layout: str, seed: int) -> int:
    """Interleaved growth + eviction churn; returns final gather runs."""
    rng = np.random.default_rng(seed)
    B, MP, ps = 8, 8, 16
    c = PagedKVCache(B, MP, ps, layout=layout)
    pos = np.zeros(B, dtype=int)
    for s in range(B):
        c.ensure_pos(s, 0)
    for _ in range(400):
        for s in range(B):
            pos[s] += 1
            if pos[s] >= MP * ps - 1:
                c.free_slot(s)
                pos[s] = int(rng.integers(0, ps))
            c.ensure_pos(s, int(pos[s]))
        if rng.random() < 0.05:
            s = int(rng.integers(0, B))
            c.free_slot(s)
            pos[s] = 0
            c.ensure_pos(s, 0)
    return c.gather_runs()


def run() -> list[dict]:
    rows = []
    cases = [
        ("gqa", "tinyllama-1.1b", 6, 16),
        ("mla", "deepseek-v2-236b", 4, 12),
    ]
    rng = np.random.default_rng(0)
    for short, arch, n_req, max_new in cases:
        cfg = get_reduced(arch, dtype="float32")
        params = init_params(jax.random.PRNGKey(0), cfg)
        prompts = [
            rng.integers(0, cfg.vocab_size, size=int(rng.integers(2, 8))).tolist()
            for _ in range(n_req)
        ]
        outs = {}
        perf = {}
        for name, kw in MODES:
            _drive(cfg, params, kw, prompts, max_new)  # cold: trace+compile
            outs[name], dt, steps = _drive(cfg, params, kw, prompts, max_new)
            toks = sum(len(o) for o in outs[name])
            perf[name] = (toks / dt if dt else 0.0, dt / max(steps, 1) * 1e3)
        for name, _ in MODES:
            ok = outs[name] == outs["dense"]
            tps, step_ms = perf[name]
            rows.append({
                "bench": "serving",
                "name": f"{short}_{name}",
                "value": round(tps, 1),
                "derived": f"tok/s; step_ms={step_ms:.1f}; "
                           f"differential_ok={ok}; slots=4; max_new={max_new}",
            })

    # compiled-forward batched prefill vs chunked masked decode at a
    # long prompt (>= 512): one batched dispatch must beat the chunk
    # loop on admission tokens/sec, token-identical outputs (the CI
    # gate enforces both)
    cfg = get_reduced("tinyllama-1.1b", dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    plen = 512
    pf_prompts = [
        rng.integers(0, cfg.vocab_size, size=plen).tolist() for _ in range(2)
    ]
    pf = {}
    pf_outs = {}
    for mode in ("chunked", "compiled"):
        pf[mode], pf_outs[mode] = _time_prefill(
            cfg, params, mode, pf_prompts, max_len=plen + 32
        )
    pf_ok = pf_outs["compiled"] == pf_outs["chunked"]
    for mode in ("chunked", "compiled"):
        rows.append({
            "bench": "serving",
            "name": f"prefill_{mode}",
            "value": round(pf[mode], 1),
            "derived": f"prefill tok/s; prompt={plen}; B=2; "
                       f"differential_ok={pf_ok}; "
                       f"speedup={pf['compiled'] / max(pf['chunked'], 1e-9):.2f}x",
        })

    # COW prefix sharing: shared-prefix admission must allocate strictly
    # fewer pages than unshared, with greedy outputs unchanged.  The
    # 44-token common prefix ends mid-page (ps=16), so divergent tails
    # land inside a shared page and exercise the COW path.
    sh_prefix = rng.integers(0, cfg.vocab_size, size=44).tolist()
    sh_prompts = [
        sh_prefix + rng.integers(0, cfg.vocab_size, size=6).tolist()
        for _ in range(6)
    ]
    sh_outs = {}
    sh_pages = {}
    for label, flag in (("shared", True), ("unshared", False)):
        sh_outs[label], sh_pages[label] = _drive_sharing(
            cfg, params, flag, sh_prompts, max_new=4
        )
    sh_ok = sh_outs["shared"] == sh_outs["unshared"]
    for label in ("shared", "unshared"):
        rows.append({
            "bench": "serving",
            "name": f"pages_alloc_{label}",
            "value": sh_pages[label],
            "derived": f"pages allocated; 6 reqs / 2 slots; prefix=44; "
                       f"differential_ok={sh_ok}; fewer=better",
        })

    # sharing-churn locality bound: donor pages + COW copies must keep
    # the decode gather stream within 2x of unshared allocation
    sc_s = float(np.mean([_sharing_churn("shared", s) for s in range(5)]))
    sc_u = float(np.mean([_sharing_churn("unshared", s) for s in range(5)]))
    ratio = sc_s / max(sc_u, 1e-9)
    rows.append({
        "bench": "serving_pages",
        "name": "gather_runs_sharing_ratio",
        "value": round(ratio, 3),
        "derived": f"shared({sc_s:.1f}) / unshared({sc_u:.1f}) mean gather "
                   f"runs over 5 churn seeds; within_bound={ratio < 2.0}",
    })

    # page-layout locality: curve map vs first-fit under serving churn
    h = float(np.mean([_layout_churn("hilbert", s) for s in range(10)]))
    n = float(np.mean([_layout_churn("naive", s) for s in range(10)]))
    for layout, runs in (("hilbert", h), ("naive", n)):
        rows.append({
            "bench": "serving_pages",
            "name": f"gather_runs_{layout}",
            "value": round(runs, 1),
            "derived": f"mean contiguous runs over 10 churn seeds; "
                       f"fewer=better; hilbert_wins={h < n}",
        })
    return rows
