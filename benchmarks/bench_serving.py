"""E6 — serving decode: dense vs Hilbert-paged KV cache vs flash-paged.

Drives the continuous-batching ``ServeEngine`` through the same request
stream in its three cache/attention modes and reports tokens/sec and
per-step decode latency for each, for a GQA arch and an MLA arch.  Every
mode row is stamped ``differential_ok`` — greedy outputs token-identical
to the retained dense XLA path (the CI bench gate requires True), so
the perf trajectory can never drift away from a correctness anchor.

Also reports the page-layout locality claim behind the design: under
interleaved slot growth with eviction churn, the curve page layout's
decode gather stream decomposes into fewer contiguous memory runs than
naive first-fit allocation (Netay's clustering property applied to KV
paging; ``PagedKVCache.gather_runs``).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import init_params
from repro.serve import PagedKVCache, ServeEngine

MODES = [
    ("dense", dict(paged=False)),
    ("paged", dict(paged=True, attn_impl="xla")),
    ("flash_paged", dict(paged=True, attn_impl="flash")),
]


def _drive(cfg, params, mode_kw, prompts, max_new):
    """One full serve of ``prompts``.  Returns (outputs, decode_s, steps).

    Decode time excludes admission/prefill ticks — per-step latency is
    the steady-state metric a serving deployment sees."""
    eng = ServeEngine(
        cfg, params, num_slots=4, max_len=96, page_size=16, **mode_kw
    )
    reqs = [eng.submit(list(p), max_new=max_new) for p in prompts]
    steps = 0
    decode_s = 0.0
    while (eng._queue or eng.active.any()) and steps < 10_000:
        t0 = time.perf_counter()
        eng.step()
        decode_s += time.perf_counter() - t0
        steps += 1
    return [r.out for r in reqs], decode_s, steps


def _layout_churn(layout: str, seed: int) -> int:
    """Interleaved growth + eviction churn; returns final gather runs."""
    rng = np.random.default_rng(seed)
    B, MP, ps = 8, 8, 16
    c = PagedKVCache(B, MP, ps, layout=layout)
    pos = np.zeros(B, dtype=int)
    for s in range(B):
        c.ensure_pos(s, 0)
    for _ in range(400):
        for s in range(B):
            pos[s] += 1
            if pos[s] >= MP * ps - 1:
                c.free_slot(s)
                pos[s] = int(rng.integers(0, ps))
            c.ensure_pos(s, int(pos[s]))
        if rng.random() < 0.05:
            s = int(rng.integers(0, B))
            c.free_slot(s)
            pos[s] = 0
            c.ensure_pos(s, 0)
    return c.gather_runs()


def run() -> list[dict]:
    rows = []
    cases = [
        ("gqa", "tinyllama-1.1b", 6, 16),
        ("mla", "deepseek-v2-236b", 4, 12),
    ]
    rng = np.random.default_rng(0)
    for short, arch, n_req, max_new in cases:
        cfg = get_reduced(arch, dtype="float32")
        params = init_params(jax.random.PRNGKey(0), cfg)
        prompts = [
            rng.integers(0, cfg.vocab_size, size=int(rng.integers(2, 8))).tolist()
            for _ in range(n_req)
        ]
        outs = {}
        perf = {}
        for name, kw in MODES:
            _drive(cfg, params, kw, prompts, max_new)  # cold: trace+compile
            outs[name], dt, steps = _drive(cfg, params, kw, prompts, max_new)
            toks = sum(len(o) for o in outs[name])
            perf[name] = (toks / dt if dt else 0.0, dt / max(steps, 1) * 1e3)
        for name, _ in MODES:
            ok = outs[name] == outs["dense"]
            tps, step_ms = perf[name]
            rows.append({
                "bench": "serving",
                "name": f"{short}_{name}",
                "value": round(tps, 1),
                "derived": f"tok/s; step_ms={step_ms:.1f}; "
                           f"differential_ok={ok}; slots=4; max_new={max_new}",
            })

    # page-layout locality: curve map vs first-fit under serving churn
    h = float(np.mean([_layout_churn("hilbert", s) for s in range(10)]))
    n = float(np.mean([_layout_churn("naive", s) for s in range(10)]))
    for layout, runs in (("hilbert", h), ("naive", n)):
        rows.append({
            "bench": "serving_pages",
            "name": f"gather_runs_{layout}",
            "value": round(runs, 1),
            "derived": f"mean contiguous runs over 10 churn seeds; "
                       f"fewer=better; hilbert_wins={h < n}",
        })
    return rows
