"""E1 — paper Fig. 1(e): cache misses vs cache size per traversal order.

The paper's experiment: a pairwise loop touches objects i and j; an LRU
cache of varying size holds recently-used objects; count misses.  The
claim: Hilbert order yields a dramatically lower miss rate, especially at
realistic cache sizes (5-20% of the object count).  We reproduce it for
all curves, plus the operand-reload economy (the Pallas revisit rule).
"""
from __future__ import annotations

import numpy as np

from repro.core import miss_curve, operand_reloads, tile_schedule

CURVES = ("row", "zigzag", "zorder", "gray", "hilbert", "fur", "peano")


def run(order: int = 6) -> list[dict]:
    n = 1 << order  # 64x64 grid, 4096 steps
    fracs = (0.02, 0.05, 0.10, 0.20, 0.50)
    sizes = [max(2, int(2 * n * f)) for f in fracs]  # cache counts objects
    rows = []
    miss_at = {}
    for curve in CURVES:
        sched = tile_schedule(curve, n, n)
        mc = miss_curve(sched, sizes)
        reloads = operand_reloads(sched, 0) + operand_reloads(sched, 1)
        miss_at[curve] = mc
        for size, misses in mc.items():
            rows.append({
                "bench": "locality",
                "name": f"{curve}_misses_c{size}",
                "value": misses,
                "derived": f"cache={size}({size/(2*n):.0%} of objects)",
            })
        rows.append({
            "bench": "locality",
            "name": f"{curve}_operand_reloads",
            "value": reloads,
            "derived": f"min possible={n*n+1}",
        })
    # the paper's headline: hilbert vs row at realistic cache sizes
    for f, size in zip(fracs, sizes):
        h, r = miss_at["hilbert"][size], miss_at["row"][size]
        rows.append({
            "bench": "locality",
            "name": f"hilbert_vs_row_speedup_c{f:.2f}",
            "value": round(r / max(h, 1), 2),
            "derived": f"row={r} hilbert={h}",
        })
    return rows
