"""E1 — paper Fig. 1(e): cache misses vs cache size per traversal order.

The paper's experiment: a pairwise loop touches objects i and j; an LRU
cache of varying size holds recently-used objects; count misses.  The
claim: Hilbert order yields a dramatically lower miss rate, especially at
realistic cache sizes (5-20% of the object count).  We reproduce it for
all curves, plus the operand-reload economy (the Pallas revisit rule).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    available_curves,
    miss_counts,
    miss_curve,
    operand_reloads,
    operand_reloads_nd,
    tile_schedule,
    tile_schedule_nd,
)

CURVES = (
    "row", "zigzag", "zorder", "gray", "hilbert", "fur", "peano",
    "harmonious", "hcyclic",
)


def _tile_stream_3d(sched):
    """Tile-access stream of the 3-D matmul: per (i, j, k) step the
    kernel touches A(i,k), B(k,j) and the accumulator tile C(i,j)."""
    for i, j, k in np.asarray(sched):
        yield ("A", int(i), int(k))
        yield ("B", int(k), int(j))
        yield ("C", int(i), int(j))


def run_3d(side: int = 16) -> list[dict]:
    """Locality economy of 3-D (i, j, k) matmul schedules.

    Any unit-step order keeps one of A/B/C resident per step (the
    cache-size-1 Pallas revisit rule); the Hilbert order additionally
    clusters revisits, so tile-LRU caches beyond one block keep winning
    — the paper's Fig. 1(e) claim lifted to 3-D."""
    rows = []
    shape = (side, side, side)
    cache_sizes = (8, 32, 128)
    for curve in available_curves(3):
        sched = tile_schedule_nd(curve, shape)
        a = operand_reloads_nd(sched, (0, 2))
        b = operand_reloads_nd(sched, (2, 1))
        o = operand_reloads_nd(sched, (0, 1))
        rows.append({
            "bench": "locality",
            "name": f"{curve}_3d_operand_reloads",
            "value": a + b + o,
            "derived": f"A={a};B={b};C={o};min={2 * side**3 + 1}",
        })
        # one reuse-distance pass covers every cache size (not one LRU
        # simulation per size)
        mc = miss_counts(list(_tile_stream_3d(sched)), cache_sizes)
        for cs, misses in mc.items():
            rows.append({
                "bench": "locality",
                "name": f"{curve}_3d_tile_misses_c{cs}",
                "value": misses,
                "derived": f"tile-LRU cache={cs} blocks",
            })
    return rows


def run(order: int = 6) -> list[dict]:
    n = 1 << order  # 64x64 grid, 4096 steps
    fracs = (0.02, 0.05, 0.10, 0.20, 0.50)
    sizes = [max(2, int(2 * n * f)) for f in fracs]  # cache counts objects
    rows = []
    miss_at = {}
    for curve in CURVES:
        sched = tile_schedule(curve, n, n)
        mc = miss_curve(sched, sizes)
        reloads = operand_reloads(sched, 0) + operand_reloads(sched, 1)
        miss_at[curve] = mc
        for size, misses in mc.items():
            rows.append({
                "bench": "locality",
                "name": f"{curve}_misses_c{size}",
                "value": misses,
                "derived": f"cache={size}({size/(2*n):.0%} of objects)",
            })
        rows.append({
            "bench": "locality",
            "name": f"{curve}_operand_reloads",
            "value": reloads,
            "derived": f"min possible={n*n+1}",
        })
    # the paper's headline: hilbert vs row at realistic cache sizes
    for f, size in zip(fracs, sizes):
        h, r = miss_at["hilbert"][size], miss_at["row"][size]
        rows.append({
            "bench": "locality",
            "name": f"hilbert_vs_row_speedup_c{f:.2f}",
            "value": round(r / max(h, 1), 2),
            "derived": f"row={r} hilbert={h}",
        })
    rows.extend(run_3d())
    return rows
