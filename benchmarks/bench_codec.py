"""E2 — coding throughput (paper §3/§5): order-value computation and
curve generation rates.

The paper's point: the Mealy automaton costs O(log n) per conversion —
too slow inside a loop — while the non-recursive Fig. 5 generator (and
its data-parallel reformulation here) is O(1)/step.  We measure all of
them plus the device-side jnp codec.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    clip_path_nd,
    fgf_box_nd,
    gray_encode,
    hilbert_decode,
    hilbert_decode_nd,
    hilbert_encode,
    hilbert_encode_jax,
    hilbert_encode_nd,
    hilbert_encode_nd_jax,
    hilbert_path_recursive,
    hilbert_path_vectorised,
    peano_encode,
    zorder_encode,
    zorder_encode_nd,
)


def _rate(fn, n_items: int, repeat: int = 5) -> float:
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    dt = (time.perf_counter() - t0) / repeat
    return n_items / dt


def _best_rate(fn, n_items: int, repeat: int = 5, rounds: int = 5) -> float:
    """Best-of-rounds rate: robust to scheduler noise for sub-ms work."""
    fn()  # warmup
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        for _ in range(repeat):
            fn()
        best = min(best, (time.perf_counter() - t0) / repeat)
    return n_items / best


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    N = 1 << 18
    i = rng.integers(0, 1 << 14, size=N)
    j = rng.integers(0, 1 << 14, size=N)
    h = np.asarray(hilbert_encode(i, j))
    ij32 = jnp.asarray(i, jnp.int32), jnp.asarray(j, jnp.int32)

    rows = []

    def add(name, rate, derived=""):
        rows.append({
            "bench": "codec", "name": name,
            "value": round(rate / 1e6, 2), "derived": derived or "Mops/s",
        })

    add("hilbert_encode_np", _rate(lambda: hilbert_encode(i, j, nbits=14), N),
        "Mealy automaton, vectorised")
    add("hilbert_decode_np", _rate(lambda: hilbert_decode(h, nbits=14), N))
    add("zorder_encode_np", _rate(lambda: zorder_encode(i, j), N),
        "bit interleave (PDEP-in-software)")
    add("gray_encode_np", _rate(lambda: gray_encode(i, j), N))
    add("peano_encode_np", _rate(lambda: peano_encode(i, j, ndigits=9), N),
        "3-adic automaton")

    enc = jax.jit(lambda a, b: hilbert_encode_jax(a, b, nbits=14))
    enc(*ij32).block_until_ready()
    add("hilbert_encode_jax",
        _rate(lambda: enc(*ij32).block_until_ready(), N),
        "device-side fori_loop codec")

    # d-dimensional codec (Butz/Lawder rotate-reflect), d in {2, 3}
    for d, nb in ((2, 14), (3, 9)):
        c = rng.integers(0, 1 << nb, size=(N, d))
        h_nd = np.asarray(hilbert_encode_nd(c, nb))
        add(f"hilbert_encode_nd_d{d}",
            _rate(lambda c=c, nb=nb: hilbert_encode_nd(c, nb), N),
            f"d={d} rotate-reflect, vectorised")
        add(f"hilbert_decode_nd_d{d}",
            _rate(lambda h=h_nd, d=d, nb=nb: hilbert_decode_nd(h, d, nb), N))
        add(f"zorder_encode_nd_d{d}",
            _rate(lambda c=c, nb=nb: zorder_encode_nd(c, nb), N),
            f"d={d} generic bit interleave")
        c32 = jnp.asarray(c, jnp.int32)
        encd = jax.jit(lambda x, nb=nb: hilbert_encode_nd_jax(x, nb))
        encd(c32).block_until_ready()
        add(f"hilbert_encode_nd_jax_d{d}",
            _rate(lambda: encd(c32).block_until_ready(), N),
            f"d={d} device-side fori_loop codec")

    # curve generation (pairs/s)
    order = 9  # 512x512 = 262144 pairs
    n2 = 1 << (2 * order)
    add("gen_recursive_cfg", _rate(lambda: hilbert_path_recursive(order), n2),
        "paper §4 CFG")
    add("gen_vectorised_fig5", _rate(lambda: hilbert_path_vectorised(order), n2),
        "beyond-paper data-parallel Fig.5")

    # gen_nd — d-dim path generation for shapes just above a power of two:
    # clip baseline decodes the whole 2^(d·L) cover and filters (paper §6),
    # the fgf_nd jump-over walker is output-linear (paper §6.2 in d dims).
    # Emitted cells/s, so the speedup column is the wall-clock ratio.
    for shape in ((129, 129), (9, 9, 9), (17, 17, 17), (9, 9, 9, 9)):
        d = len(shape)
        cells = int(np.prod(shape))
        clip = _best_rate(lambda: clip_path_nd(hilbert_decode_nd, shape),
                          cells, repeat=3)
        jump = _best_rate(lambda: fgf_box_nd(shape), cells, repeat=3)
        tag = "x".join(map(str, shape))
        add(f"gen_nd_clip_d{d}_{tag}", clip, f"d={d} cover decode+filter")
        add(f"gen_nd_jump_d{d}_{tag}", jump, f"d={d} FGF jump-over")
        rows.append({
            "bench": "codec", "name": f"gen_nd_speedup_d{d}_{tag}",
            "value": round(jump / clip, 2),
            "derived": f"jump-over vs clip; cells={cells}",
        })
    return rows
